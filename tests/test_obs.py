"""The observability layer: tracer fast path, timelines, exporters,
flight recorder, autotune audit trail, and the serving/training wiring.

The two contracts everything else leans on:

* **disabled fast path** — tracing off means zero recorded events and
  near-zero cost (one module-flag check; ``span()`` returns the shared
  no-op singleton, no allocation);
* **timeline completeness** — with tracing on, every request the engine
  admits reaches exactly one terminal timeline event, and the terminal
  counts reconcile against the serving conservation ledger.
"""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.models import gan
from repro.obs import trace as obs
from repro.obs.audit import AuditTrail, audit_path, set_trail
from repro.obs.export import (
    chrome_trace,
    metric_name,
    parse_prometheus_text,
    prometheus_text,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.obs.flight_recorder import FlightRecorder
from repro.obs.timeline import RequestTimeline, TimelineStore
from repro.obs.trace import NOOP_SPAN, Tracer, percentiles
from repro.serve import BucketPolicy, GanEngine, GenRequest, QueueFull

TINY = gan.GANConfig("tiny", 8, ((4, 4, 4), (8, 4, 3)))


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture
def iso_tracer():
    """An isolated enabled tracer installed as the process global;
    restores the previous tracer and flag afterwards."""
    tracer = Tracer()
    prev = obs.set_tracer(tracer)
    was = obs.enabled()
    obs.enable()
    yield tracer
    obs.set_tracer(prev)
    if was:
        obs.enable()
    else:
        obs.disable()


@pytest.fixture
def iso_disabled():
    """An isolated tracer with tracing forced OFF (the fast-path tests)."""
    tracer = Tracer()
    prev = obs.set_tracer(tracer)
    was = obs.enabled()
    obs.disable()
    yield tracer
    obs.set_tracer(prev)
    if was:
        obs.enable()
    else:
        obs.disable()


# ------------------------------------------------------------------ tracer


def test_percentiles_summary_and_empty():
    p = percentiles([1.0, 2.0, 3.0, 4.0])
    assert p["p50"] == 2.5 and p["max"] == 4.0 and p["mean"] == 2.5
    empty = percentiles([])
    assert set(empty) == {"p50", "p95", "p99", "mean", "max"}
    assert all(v == 0.0 for v in empty.values())


def test_span_nesting_records_depth_and_duration():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("outer", model="tiny"):
        clock.advance(1.0)
        with tr.span("inner") as sp:
            sp.set(bucket=4)
            clock.advance(0.5)
    names = [s["name"] for s in tr.spans]
    assert names == ["inner", "outer"]      # children close first
    inner, outer = tr.spans
    assert inner["depth"] == 1 and outer["depth"] == 0
    assert inner["dur"] == 0.5 and outer["dur"] == 1.5
    assert inner["args"]["bucket"] == 4
    assert outer["args"]["model"] == "tiny"
    assert tr.span_names() == {"inner": 1, "outer": 1}
    assert tr.span_walls("outer") == [1.5]


def test_span_exception_tagged_and_propagated():
    tr = Tracer(clock=FakeClock())
    with pytest.raises(RuntimeError):
        with tr.span("doomed"):
            raise RuntimeError("boom")
    assert tr.spans[0]["args"]["error"] == "RuntimeError"


def test_counters_gauges_observations_bounded():
    tr = Tracer(max_observations=4)
    tr.counter("hits")
    tr.counter("hits", 2.0)
    tr.gauge("depth", 7)
    for i in range(10):
        tr.observe("wall_s", float(i))
    assert tr.counters["hits"] == 3.0
    assert tr.gauges["depth"] == 7.0
    assert list(tr.observations["wall_s"]) == [6.0, 7.0, 8.0, 9.0]
    s = tr.summary()
    assert s["counters"]["hits"] == 3.0
    assert s["observations"]["wall_s"]["max"] == 9.0


def test_event_ring_bounded():
    tr = Tracer(clock=FakeClock(), max_events=3)
    for i in range(5):
        tr.event("tick", i=i)
    assert [e["args"]["i"] for e in tr.instants] == [2, 3, 4]


def test_sink_sees_spans_and_events_until_removed():
    tr = Tracer(clock=FakeClock())
    seen = []
    tr.add_sink(lambda kind, rec: seen.append((kind, rec["name"])))
    with tr.span("s"):
        pass
    tr.event("e")
    assert seen == [("span", "s"), ("event", "e")]
    tr.remove_sink(tr._sinks[0])
    tr.event("after")
    assert len(seen) == 2


def test_disabled_helpers_record_nothing(iso_disabled):
    assert obs.span("x") is NOOP_SPAN       # the shared no-op singleton
    with obs.span("x", a=1) as sp:
        sp.set(b=2)                          # no-op, no error
    obs.counter("c")
    obs.gauge("g", 1.0)
    obs.observe("o", 1.0)
    obs.event("e")
    assert len(iso_disabled.spans) == 0
    assert len(iso_disabled.instants) == 0
    assert not iso_disabled.counters
    assert not iso_disabled.gauges
    assert not iso_disabled.observations


def test_disabled_span_fast_path_cost(iso_disabled):
    """The disabled path is one flag check + a shared singleton: 100k
    span entries must be far under a millisecond each (loose absolute
    bound — this pins 'no lock, no allocation', not a benchmark)."""
    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("hot", a=1):
            pass
    wall = time.perf_counter() - t0
    assert wall < 1.0, f"disabled span path too slow: {wall:.3f}s / {n}"


def test_enabled_helpers_hit_installed_tracer(iso_tracer):
    with obs.span("top", who="test"):
        obs.counter("n")
        obs.observe("w", 0.25)
        obs.event("mark", k=1)
    assert iso_tracer.span_names() == {"top": 1}
    assert iso_tracer.counters["n"] == 1.0
    assert list(iso_tracer.observations["w"]) == [0.25]
    assert iso_tracer.instants[0]["args"]["k"] == 1


# ---------------------------------------------------------------- timeline


def test_timeline_rejects_unknown_event():
    tl = RequestTimeline(0)
    with pytest.raises(ValueError, match="unknown timeline event"):
        tl.add("teleport", 0.0)


def test_timeline_completeness_contract():
    served = RequestTimeline(1)
    served.add("admit", 0.0)
    assert not served.complete                 # no terminal yet
    served.add("reply", 1.0)
    assert served.complete and served.terminal_event == "reply"

    rejected = RequestTimeline(2)
    rejected.add("reject", 0.0)
    assert rejected.complete                   # bare reject is complete

    orphan = RequestTimeline(3)
    orphan.add("reply", 1.0)                   # terminal without admit
    assert not orphan.complete


def test_timeline_segments_decompose_wall():
    tl = RequestTimeline(0, model="tiny")
    tl.add("admit", 1.0)
    tl.add("pack", 1.25, bucket=4)
    tl.add("dispatch", 1.35)
    tl.add("slice", 1.85)
    tl.add("reply", 1.9)
    seg = tl.segments()
    assert seg["queue_s"] == 0.25
    assert seg["dispatch_s"] == pytest.approx(0.1)
    assert seg["execute_s"] == 0.5
    assert seg["total_s"] == pytest.approx(0.9)
    d = tl.to_dict()
    assert d["terminal"] == "reply" and d["complete"] and d["model"] == "tiny"


def test_store_moves_terminal_to_done_and_bounds_ring():
    store = TimelineStore(capacity=3)
    store.event(0, "admit", 0.0, model="tiny")
    assert store.active == 1 and len(store) == 1
    store.event(0, "reply", 1.0)
    assert store.active == 0 and len(store) == 1
    assert store.get(0).complete
    for rid in range(1, 6):                    # overflow the done ring
        store.event(rid, "admit", float(rid))
        store.event(rid, "reply", float(rid) + 0.5)
    assert len(store) == 3                     # oldest dropped
    assert store.get(0) is None
    assert store.get(5) is not None
    assert store.terminal_counts()["reply"] == 3


def test_store_incomplete_lists_contract_violators():
    store = TimelineStore()
    store.event(0, "admit", 0.0)
    store.event(1, "admit", 0.0)
    store.event(1, "reply", 1.0)
    bad = store.incomplete()
    assert [tl.rid for tl in bad] == [0]


def test_reconcile_against_conservation_ledger():
    store = TimelineStore()
    store.event(0, "admit", 0.0)
    store.event(0, "reply", 1.0)
    store.event(1, "admit", 0.0)
    store.event(1, "expire", 2.0)
    store.event("reject#1", "reject", 0.5)
    ledger = {"done": 1, "expired": 1, "rejected": 1, "failed": 0,
              "malformed": 0}
    rec = store.reconcile(ledger)
    assert rec["ok"] and not rec["mismatches"]
    rec = store.reconcile({**ledger, "done": 2})
    assert not rec["ok"]
    assert rec["mismatches"]["reply"] == {"timeline": 1, "ledger": 2}


# --------------------------------------------------------------- exporters


def _toy_tracer():
    clock = FakeClock(100.0)
    tr = Tracer(clock=clock)
    with tr.span("serve.dispatch", bucket=4):
        clock.advance(0.002)
    tr.event("replica.transition", old="HEALTHY", new="SUSPECT")
    tr.counter("serve.admitted", 5)
    tr.gauge("serve.queue_depth", 2)
    for v in (0.001, 0.002, 0.004):
        tr.observe("serve.latency_s", v)
    return tr


def test_chrome_trace_structure_and_rebased_timestamps(tmp_path):
    tr = _toy_tracer()
    store = TimelineStore()
    store.event(7, "admit", 100.0005, model="tiny")
    store.event(7, "reply", 100.003)
    blob = chrome_trace(tr, timeline=store)
    assert validate_chrome_trace(blob) == []
    events = blob["traceEvents"]
    assert min(e["ts"] for e in events) == 0.0          # rebased
    assert events == sorted(events, key=lambda e: e["ts"])
    phases = {e["ph"] for e in events}
    assert phases == {"X", "i", "C"}
    x = next(e for e in events if e["ph"] == "X")
    assert x["dur"] == pytest.approx(2000.0)            # 2ms in us
    # timeline instants ride a separate pid track named by model#rid
    tl_events = [e for e in events if "tiny#7" in e["name"]]
    assert {e["name"].split()[0] for e in tl_events} == {"admit", "reply"}
    assert all(e["pid"] == 2 for e in tl_events)

    path = tmp_path / "trace.json"
    write_chrome_trace(tr, path, timeline=store)
    loaded = json.loads(path.read_text())
    assert validate_chrome_trace(loaded) == []
    assert len(loaded["traceEvents"]) == len(events)


def test_validate_chrome_trace_flags_malformed():
    assert validate_chrome_trace({}) == ["missing traceEvents"]
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": 0, "pid": 1,
                            "tid": 1}]}
    assert any("missing dur" in p for p in validate_chrome_trace(bad))
    missing = {"traceEvents": [{"ph": "i", "ts": 0, "pid": 1, "tid": 1}]}
    assert any("missing 'name'" in p for p in validate_chrome_trace(missing))


def test_metric_name_sanitized():
    assert metric_name("serve.latency_s") == "serve_latency_s"
    assert metric_name("9lives") == "_9lives"
    assert metric_name("ok_name") == "ok_name"


def test_prometheus_text_round_trips():
    text = prometheus_text(_toy_tracer(), extra_gauges={"serve.extra": 1.5})
    parsed = parse_prometheus_text(text)
    m, t = parsed["metrics"], parsed["types"]
    assert m["serve_admitted"] == 5.0
    assert t["serve_admitted"] == "counter"
    assert m["serve_queue_depth"] == 2.0
    assert m["serve_extra"] == 1.5
    assert t["serve_latency_s"] == "summary"
    assert m[("serve_latency_s", 'quantile="0.5"')] == pytest.approx(0.002)
    assert m["serve_latency_s_sum"] == pytest.approx(0.007)
    assert m["serve_latency_s_count"] == 3


def test_parse_prometheus_rejects_malformed_lines():
    with pytest.raises(ValueError, match="malformed sample"):
        parse_prometheus_text("not a metric line at all\n")
    with pytest.raises(ValueError, match="malformed comment"):
        parse_prometheus_text("# HELLO\n")


# --------------------------------------------------------- flight recorder


def test_recorder_ring_bounded_and_snapshot():
    rec = FlightRecorder(capacity=3, clock=FakeClock())
    for i in range(5):
        rec.record("tick", i=i)
    assert len(rec) == 3
    assert [e["i"] for e in rec.snapshot()] == [2, 3, 4]


def test_recorder_dump_writes_artifact(tmp_path):
    rec = FlightRecorder(capacity=8, clock=FakeClock(5.0),
                         dump_dir=str(tmp_path))
    rec.record("train.step", step=3)
    path = rec.dump("nan_guard", extra={"step": 3})
    assert rec.dumps == [path]
    blob = FlightRecorder.load(path)
    assert blob["trigger"] == "nan_guard"
    assert blob["n_events"] == 1
    assert blob["events"][0]["kind"] == "train.step"
    assert blob["extra"] == {"step": 3}
    assert Path(path).name == "flight_001_nan_guard.json"
    # trigger strings with separators stay filesystem-safe
    p2 = rec.dump("replica_dead:r0")
    assert Path(p2).name == "flight_002_replica_dead_r0.json"


def test_recorder_shadows_tracer_when_attached():
    tr = Tracer(clock=FakeClock())
    rec = FlightRecorder(clock=FakeClock())
    rec.attach(tr)
    with tr.span("s"):
        pass
    tr.event("e")
    kinds = [e["kind"] for e in rec.snapshot()]
    assert kinds == ["trace.span", "trace.event"]
    rec.detach(tr)
    tr.event("after")
    assert len(rec) == 2


# ------------------------------------------------------------- audit trail


def test_audit_path_resolution(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_AUDIT", "/x/audit.jsonl")
    assert audit_path() == "/x/audit.jsonl"
    monkeypatch.delenv("REPRO_AUTOTUNE_AUDIT")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "/y/cache.json")
    assert audit_path() == "/y/cache.audit.jsonl"


def test_audit_record_margin_and_candidate_forms(tmp_path):
    trail = AuditTrail(path=None)
    entry = {"method": "unified", "time_s": 1.0, "source": "measured",
             "candidates": {"unified": 1.0, "conventional": 1.3},
             "bm": 8}
    rec = trail.record_decision(kind="layer", key="k1", direction="fwd",
                                entry=entry, backend="cpu")
    assert rec["winner"] == "unified"
    assert rec["margin"] == pytest.approx(1.3)
    assert [c["method"] for c in rec["candidates"]] == [
        "unified", "conventional"]
    assert rec["tiles"] == {"bm": 8}
    # nested per-tile candidate times: the best tile stands in
    nested = {"method": "gemm", "time_s": 0.5,
              "candidates": {"gemm": {"8x8": 0.5, "16x16": 0.7},
                             "lax": 0.6}}
    rec2 = trail.record_decision(kind="layer", key="k2", direction="bwd",
                                 entry=nested)
    assert rec2["candidates"][0] == {"method": "gemm", "time_s": 0.5}
    assert rec2["margin"] == pytest.approx(1.2)
    # a single candidate has no runner-up: margin is None
    solo = trail.record_decision(
        kind="pair", key="k3", direction="pair",
        entry={"method": "only", "time_s": 1.0,
               "candidates": {"only": 1.0}})
    assert solo["margin"] is None


def test_audit_persists_jsonl_and_queries(tmp_path, monkeypatch):
    audit = tmp_path / "audit.jsonl"
    monkeypatch.setenv("REPRO_AUTOTUNE_AUDIT", str(audit))
    trail = AuditTrail(path="auto", capacity=2)
    for i, d in enumerate(("fwd", "bwd", "fwd")):
        trail.record_decision(
            kind="layer", key=f"layer{i}", direction=d,
            entry={"method": "m", "time_s": 1.0, "candidates": {"m": 1.0}})
    # in-memory ring bounded at 2; the JSONL keeps everything
    assert len(trail.records) == 2
    assert len(AuditTrail.load(audit)) == 3
    assert [r["key"] for r in trail.query(direction="fwd")] == ["layer2"]
    assert [r["key"] for r in trail.query(key="layer1")] == ["layer1"]
    assert len(trail.query(last=1)) == 1
    # ephemeral decisions (persist=False) never touch the file
    trail.record_decision(
        kind="layer", key="ephemeral", direction="step",
        entry={"method": "m", "time_s": 1.0}, persist=False)
    assert len(AuditTrail.load(audit)) == 3


def test_audit_cli_queries_jsonl(tmp_path):
    audit = tmp_path / "audit.jsonl"
    trail = AuditTrail(path=str(audit))
    trail.record_decision(
        kind="layer", key="tcup L1", direction="fwd",
        entry={"method": "unified", "time_s": 0.001,
               "candidates": {"unified": 0.001, "conventional": 0.002}})
    repo_root = Path(__file__).resolve().parent.parent
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs", "audit", "--path", str(audit),
         "--direction", "fwd", "--json"],
        capture_output=True, text=True, cwd=str(repo_root),
        env={**os.environ, "PYTHONPATH": str(repo_root / "src")},
    )
    assert out.returncode == 0, out.stderr
    rows = json.loads(out.stdout)
    assert len(rows) == 1 and rows[0]["winner"] == "unified"


def test_autotune_race_records_audit_decision():
    from repro.kernels.autotune import tune_layer

    trail = AuditTrail(path=None)
    prev = set_trail(trail)
    try:
        tune_layer(1, 4, 4, 2, 3, 1,
                   methods=("conventional", "unified_reshape"),
                   repeats=1, warmup=0, persist=False)
    finally:
        set_trail(prev)
    assert len(trail.records) == 1
    rec = trail.records[0]
    assert rec["kind"] == "layer" and rec["direction"] == "fwd"
    assert rec["winner"] in ("conventional", "unified_reshape")
    assert len(rec["candidates"]) == 2
    assert rec["margin"] is not None and rec["margin"] >= 1.0


# --------------------------------------------------------- serving wiring


@pytest.fixture(scope="module")
def tiny_engine_parts():
    cfg = TINY
    params = gan.generator_init(jax.random.key(0), cfg)
    return cfg, params


def _engine(cfg, params, **policy_kw):
    policy_kw.setdefault("buckets", (1, 2, 4))
    policy_kw.setdefault("max_wait_s", 0.0)
    policy_kw.setdefault("max_queue", 64)
    eng = GanEngine(BucketPolicy(**policy_kw))
    eng.register(cfg, params, name="tiny")
    return eng


def _burst(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [GenRequest("tiny",
                       rng.standard_normal((1, cfg.z_dim)).astype(np.float32))
            for _ in range(n)]


def test_engine_disabled_records_no_timelines(tiny_engine_parts, iso_disabled):
    cfg, params = tiny_engine_parts
    eng = _engine(cfg, params)
    reqs = _burst(cfg, 4)
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    assert len(eng.timeline) == 0
    assert len(iso_disabled.spans) == 0
    assert not iso_disabled.counters


def test_engine_enabled_timelines_complete_and_reconcile(
        tiny_engine_parts, iso_tracer):
    cfg, params = tiny_engine_parts
    eng = _engine(cfg, params)
    reqs = _burst(cfg, 6)
    eng.serve(reqs)
    tls = eng.timeline.timelines()
    assert len(tls) == 6
    assert all(tl.complete and tl.terminal_event == "reply" for tl in tls)
    assert eng.timeline.incomplete() == []
    rec = eng.timeline.reconcile(eng.metrics.conservation())
    assert rec["ok"], rec
    for tl in tls:
        seg = tl.segments()
        assert seg["total_s"] >= 0.0 and "execute_s" in seg
    names = iso_tracer.span_names()
    for expected in ("serve.pack", "serve.dispatch", "serve.slice"):
        assert names.get(expected, 0) >= 1, names
    assert iso_tracer.counters["serve.admitted"] == 6.0
    assert iso_tracer.counters["serve.completed"] == 6.0


def test_engine_reject_timeline_synthetic_rid(tiny_engine_parts, iso_tracer):
    cfg, params = tiny_engine_parts
    eng = _engine(cfg, params, buckets=(1, 2), max_queue=2)
    reqs = _burst(cfg, 4, seed=3)
    shed = 0
    for r in reqs:                        # 2 admitted, then backpressure
        try:
            eng.submit(r)
        except QueueFull:
            shed += 1
    while eng.step(drain=True):
        pass
    assert shed >= 1 and eng.metrics.rejected == shed
    rejects = [tl for tl in eng.timeline.timelines()
               if tl.terminal_event == "reject"]
    assert len(rejects) == eng.metrics.rejected
    assert all(tl.complete for tl in rejects)
    assert all(str(tl.rid).startswith("reject#") for tl in rejects)
    rec = eng.timeline.reconcile(eng.metrics.conservation())
    assert rec["ok"], rec


def test_serve_metrics_publish_idempotent(tiny_engine_parts, iso_tracer):
    cfg, params = tiny_engine_parts
    eng = _engine(cfg, params)
    eng.serve(_burst(cfg, 3))
    eng.metrics.publish(iso_tracer)
    first = dict(iso_tracer.gauges)
    n_lat = len(iso_tracer.observations.get("serve.latency_s", ()))
    eng.metrics.publish(iso_tracer)       # re-publish must not double
    assert iso_tracer.gauges == first
    assert len(iso_tracer.observations["serve.latency_s"]) == n_lat
    parsed = parse_prometheus_text(prometheus_text(iso_tracer))
    assert parsed["metrics"]["serve_requests_total"] == 3.0


def test_transition_log_bounded_edge_counts_exact(tiny_engine_parts):
    from repro.serve.metrics import TRANSITION_LOG_CAP, ServeMetrics

    m = ServeMetrics()
    for i in range(TRANSITION_LOG_CAP + 50):
        m.record_transition(float(i), "r0", "HEALTHY", "SUSPECT", "probe")
    assert len(m.transitions) == TRANSITION_LOG_CAP       # ring bounded
    assert m.transition_counts["HEALTHY->SUSPECT"] == (
        TRANSITION_LOG_CAP + 50)                          # counts exact
    assert m.transitions[-1]["t"] == float(TRANSITION_LOG_CAP + 49)


def test_probe_log_stamped_with_backoff_deadline():
    from repro.serve.metrics import ServeMetrics

    m = ServeMetrics()
    m.record_probe(False, now=10.0, replica="r0", state="DEAD",
                   backoff_s=0.2, next_probe_at=10.2)
    assert m.probes == 1 and m.probe_failures == 1
    entry = m.probe_log[-1]
    assert entry["replica"] == "r0" and entry["ok"] is False
    assert entry["t"] == 10.0
    assert entry["backoff_s"] == 0.2
    assert entry["next_probe_at"] == 10.2


# -------------------------------------------------------- training wiring


def test_trainer_steps_emit_spans_and_observations(iso_tracer):
    from repro.data import SyntheticImages
    from repro.train.gan_trainer import GanTrainer, GanTrainerConfig

    tcfg = GanTrainerConfig(global_batch=2)
    data = SyntheticImages(hw=TINY.out_hw(TINY.layers[-1][0]),
                           channels=TINY.layers[-1][2], global_batch=2)
    tr = GanTrainer(TINY, tcfg, data, log_fn=lambda *a: None)
    tr.run(tr.init_state(jax.random.key(0)), steps=2)
    names = iso_tracer.span_names()
    assert names.get("train.step") == 2
    assert names.get("train.step_fn") == 2
    assert iso_tracer.counters["train.steps"] == 2.0
    assert len(iso_tracer.observations["train.step_s"]) == 2


def test_trainer_nan_guard_dumps_flight_recorder(tmp_path):
    from repro.data import SyntheticImages
    from repro.train.fault_injection import FaultInjector, FaultPlan
    from repro.train.gan_trainer import GanTrainer, GanTrainerConfig

    tcfg = GanTrainerConfig(global_batch=2)
    inj = FaultInjector(FaultPlan(nan_at_steps=(0,)))
    data = SyntheticImages(hw=TINY.out_hw(TINY.layers[-1][0]),
                           channels=TINY.layers[-1][2], global_batch=2)
    rec = FlightRecorder(dump_dir=str(tmp_path))
    tr = GanTrainer(TINY, tcfg, inj.wrap_data(data, accum=1),
                    hooks=inj, log_fn=lambda *a: None, recorder=rec)
    tr.run(tr.init_state(jax.random.key(1)), steps=2)
    assert tr.skipped_steps == 1
    assert len(rec.dumps) == 1
    blob = FlightRecorder.load(rec.dumps[0])
    assert blob["trigger"] == "nan_guard"
    assert blob["extra"]["skipped_total"] == 1
    assert any(e["kind"] == "train.step" for e in blob["events"])


def test_trainer_crash_dumps_flight_recorder(tmp_path):
    from repro.data import SyntheticImages
    from repro.train.fault_injection import (
        FaultInjector,
        FaultPlan,
        SimulatedCrash,
    )
    from repro.train.gan_trainer import GanTrainer, GanTrainerConfig

    tcfg = GanTrainerConfig(global_batch=2)
    inj = FaultInjector(FaultPlan(kill_at_step=1))
    data = SyntheticImages(hw=TINY.out_hw(TINY.layers[-1][0]),
                           channels=TINY.layers[-1][2], global_batch=2)
    rec = FlightRecorder(dump_dir=str(tmp_path))
    tr = GanTrainer(TINY, tcfg, data, hooks=inj,
                    log_fn=lambda *a: None, recorder=rec)
    with pytest.raises(SimulatedCrash):
        tr.run(tr.init_state(jax.random.key(0)), steps=4)
    assert len(rec.dumps) == 1
    blob = FlightRecorder.load(rec.dumps[0])
    assert blob["trigger"] == "crash:SimulatedCrash"


# ------------------------------------------------------------- step timer


def test_step_timer_percentiles_shared_summary():
    from repro.timing import StepTimer

    st = StepTimer()
    st.steps = [10.0, 1.0, 2.0, 3.0]    # first step is compile, skipped
    assert st.mean(skip=1) == 2.0
    assert st.median(skip=1) == 2.0
    p = st.percentiles(skip=1)
    assert p["max"] == 3.0 and p["mean"] == 2.0
    assert set(p) == {"p50", "p95", "p99", "mean", "max"}
    # skip past the end falls back to the full history, never empty
    assert st.percentiles(skip=99)["max"] == 10.0
