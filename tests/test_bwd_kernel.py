"""Segregated Pallas backward (dx + dw): structure + grad numerics.

Everything runs in interpret mode on CPU (the kernel bodies execute in
Python), validating the exact BlockSpec/grid/halo logic that runs on real
TPUs against the lax VJP of ``transpose_conv_unified`` — the same sweep the
forward suite (tests/test_fused_kernel.py) uses: odd kernels, odd paddings,
odd output extents, tiles that don't divide, bf16 vs fp32 tolerances — plus
``jax.grad`` through the custom-VJP ops layer and a small DCGAN loss.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.transpose_conv import transpose_conv_unified
from repro.kernels import ops
from repro.kernels import transpose_conv2d_bwd as tcb
from repro.kernels.transpose_conv2d_bwd import (
    transpose_conv2d_bwd_pallas,
    transpose_conv2d_dx_pallas,
)

RNG = np.random.default_rng(7)


def _rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


def _lax_grads(x, k, g, pad):
    _, vjp = jax.vjp(lambda a, b: transpose_conv_unified(a, b, pad), x, k)
    return vjp(g)


def _shapes(n_in, n_k, pad, cin, cout, b=1):
    m = 2 * n_in - n_k + 2 * pad
    x = _rand((b, n_in, n_in, cin))
    k = _rand((n_k, n_k, cin, cout))
    g = _rand((b, m, m, cout))
    return x, k, g


@pytest.mark.parametrize("n_k", [3, 5])
@pytest.mark.parametrize("pad", [1, 3])
@pytest.mark.parametrize("n_in", [5, 12])
def test_odd_kernels_odd_paddings(n_k, pad, n_in):
    """Odd kernels exercise the zero-padded sub-kernel stack (whose garbage
    taps must be sliced away from dw); odd paddings exercise the k00<->k11
    role swap (paper §3.4) in both gradients."""
    if 2 * n_in - n_k + 2 * pad <= 0:
        pytest.skip("empty output")
    x, k, g = _shapes(n_in, n_k, pad, 3, 4, b=2)
    dx_ref, dw_ref = _lax_grads(x, k, g, pad)
    dx, dw = transpose_conv2d_bwd_pallas(x, k, g, pad)
    np.testing.assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("pad", [0, 2])
def test_even_kernel_gan_paddings(pad):
    """4x4 kernels (every Table-4 GAN layer); pad=0 exercises the negative
    phase-offset path of the dx plane shift."""
    x, k, g = _shapes(6, 4, pad, 2, 3, b=2)
    dx_ref, dw_ref = _lax_grads(x, k, g, pad)
    dx, dw = transpose_conv2d_bwd_pallas(x, k, g, pad)
    np.testing.assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_in,n_k,pad", [(9, 3, 1), (7, 5, 2), (8, 5, 2)])
def test_odd_output_extents(n_in, n_k, pad):
    """Odd M: the parity planes have unequal extents; the missing last
    row/col is zero-padded and must contribute nothing to either gradient."""
    m = 2 * n_in - n_k + 2 * pad
    assert m % 2 == 1
    x, k, g = _shapes(n_in, n_k, pad, 3, 2)
    dx_ref, dw_ref = _lax_grads(x, k, g, pad)
    dx, dw = transpose_conv2d_bwd_pallas(x, k, g, pad, tile_h=3, tile_w=4)
    np.testing.assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tile_h,tile_w", [(2, 3), (3, 2), (5, 5)])
def test_tile_sizes_that_do_not_divide(tile_h, tile_w):
    """N=12 divides none of these dx tiles: the last tile row/col
    over-computes into the zero-shifted plane halo and is cropped."""
    x, k, g = _shapes(12, 4, 1, 2, 2)
    dx_ref, dw_ref = _lax_grads(x, k, g, 1)
    dx, dw = transpose_conv2d_bwd_pallas(
        x, k, g, 1, tile_h=tile_h, tile_w=tile_w, dw_tile_h=3
    )
    np.testing.assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, 1e-4),
    (jnp.bfloat16, 0.1),
])
def test_dtype_tolerance_sweep(dtype, tol):
    """bf16 primals: the cotangent is cast to the primal dtype on the host
    (bf16 MXU taps) but accumulation stays fp32 — error bounded by input
    rounding, not reduction length."""
    x, k, g = _shapes(16, 4, 2, 8, 8)
    dx_ref, dw_ref = _lax_grads(x, k, g, 2)  # fp32 reference
    dx, dw = transpose_conv2d_bwd_pallas(
        x.astype(dtype), k.astype(dtype), g, 2
    )
    assert dx.dtype == jnp.float32 and dw.dtype == jnp.float32
    np.testing.assert_allclose(dx, dx_ref, rtol=tol, atol=tol)
    np.testing.assert_allclose(dw, dw_ref, rtol=tol, atol=tol)


def test_dx_blockspec_is_spatially_tiled():
    """The dx kernel's per-grid-step load is a halo'd tile of the parity
    planes, never a full plane, and the grid walks spatial tiles."""
    captured = {}
    orig = tcb.pl.pallas_call

    def spy(kernel, **kw):
        captured["grid"] = kw["grid"]
        captured["in_block"] = kw["in_specs"][0].block_shape
        return orig(kernel, **kw)

    tcb.pl.pallas_call = spy
    try:
        x, k, g = _shapes(48, 4, 2, 2, 2)
        dx_ref, _ = _lax_grads(x, k, g, 2)
        dx = transpose_conv2d_dx_pallas(g, k, 48, 2)
        np.testing.assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)
    finally:
        tcb.pl.pallas_call = orig

    ph, b, th, tw, co = captured["in_block"]
    # N=48: default tile_h=8, halo R-1=1 -> 9-row tiles of all 4 planes
    assert ph == 4 and captured["grid"][1] > 1
    assert th < 48 and th <= 8 + 1  # tile + halo, not the plane


@pytest.mark.parametrize("pad", [1, 2])
def test_ops_grad_pallas_matches_lax(pad):
    """jax.grad through the custom-VJP ops layer: bwd="pallas" and
    bwd="lax" must agree (and match differentiating the lax unified
    implementation directly)."""
    x = _rand((1, 7, 7, 2))
    k = _rand((3, 3, 2, 3))

    def f(bwd):
        return lambda x, k: jnp.sum(
            jnp.sin(ops.transpose_conv2d_pallas(x, k, pad, None, None, bwd))
        )

    gp = jax.grad(f("pallas"), argnums=(0, 1))(x, k)
    gl = jax.grad(f("lax"), argnums=(0, 1))(x, k)
    gr = jax.grad(
        lambda x, k: jnp.sum(jnp.sin(transpose_conv_unified(x, k, pad))),
        argnums=(0, 1),
    )(x, k)
    for a, b, c in zip(gp, gl, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_ops_phase_wrapper_dispatches_pallas_bwd(pad=2):
    x = _rand((1, 6, 6, 2))
    k = _rand((4, 4, 2, 2))
    gp = jax.grad(
        lambda x: jnp.sum(
            ops.transpose_conv2d_pallas_phase(x, k, pad, "pallas") ** 2
        )
    )(x)
    gr = jax.grad(
        lambda x: jnp.sum(transpose_conv_unified(x, k, pad) ** 2)
    )(x)
    np.testing.assert_allclose(gp, gr, rtol=1e-4, atol=1e-4)


def test_unknown_bwd_method_raises():
    """A typo'd bwd selector must fail loudly, not silently run the lax
    fallback while the caller attributes the numbers to Pallas."""
    x = _rand((1, 6, 6, 2))
    k = _rand((4, 4, 2, 2))
    with pytest.raises(ValueError, match="unknown bwd"):
        jax.grad(
            lambda x: jnp.sum(
                ops.transpose_conv2d_pallas(x, k, 2, None, None, "Pallas")
            )
        )(x)


def test_lax_vjp_closure_is_cached():
    """The lax fallback must not re-trace jax.vjp per backward call: the
    jitted closure is built once per (padding, shapes, dtypes)."""
    ops._unified_vjp_fn.cache_clear()
    x = _rand((1, 6, 6, 2))
    k = _rand((4, 4, 2, 2))
    g = _rand((1, 12, 12, 2))
    ops._lax_bwd(2, (x, k, None, None), g)
    ops._lax_bwd(2, (x, k, None, None), g)
    info = ops._unified_vjp_fn.cache_info()
    assert info.misses == 1 and info.hits >= 1


def test_grad_through_dcgan_loss(tmp_path, monkeypatch):
    """jax.grad through a small DCGAN generator loss with every tconv layer
    forced onto the Pallas forward AND the Pallas backward (via tuned bwd
    cache entries) must match the unified-lax generator's gradients."""
    from repro.kernels import autotune
    from repro.models import gan

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear_cache(memory_only=True)
    cfg = dataclasses.replace(
        gan.DCGAN, layers=((4, 8, 8), (8, 8, 4))
    )
    params = gan.generator_init(jax.random.key(0), cfg)
    z = jax.random.normal(jax.random.key(1), (2, cfg.z_dim))
    for hw, cin, cout in cfg.layers:
        autotune.record(
            autotune.layer_key(2, hw, cfg.kernel, cin, cout, cfg.padding),
            {"method": "pallas", "time_s": 0.0, "source": "test"},
            direction="bwd",
        )

    def loss(params, method):
        img = gan.generator_apply(params, cfg, z, method=method)
        return jnp.mean(img ** 2)

    gp = jax.grad(lambda p: loss(p, "pallas"))(params)
    gr = jax.grad(lambda p: loss(p, "unified"))(params)
    flat_p, _ = jax.tree_util.tree_flatten(gp)
    flat_r, _ = jax.tree_util.tree_flatten(gr)
    for a, b in zip(flat_p, flat_r):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    autotune.clear_cache(memory_only=True)
