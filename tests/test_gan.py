"""GAN generator zoo (paper Table 4) + trainability of the segregated op."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gan


def _tiny(cfg, scale=16):
    layers = tuple(
        (hw, max(cin // scale, 2), max(cout // scale, 2))
        for hw, cin, cout in cfg.layers
    )
    return dataclasses.replace(cfg, layers=layers)


@pytest.mark.parametrize("name", list(gan.GAN_ZOO))
def test_generator_shapes(name):
    cfg = _tiny(gan.GAN_ZOO[name])
    params = gan.generator_init(jax.random.key(0), cfg)
    z = jax.random.normal(jax.random.key(1), (2, cfg.z_dim))
    img = gan.generator_apply(params, cfg, z, method="unified")
    last_hw, _, last_c = cfg.layers[-1]
    assert img.shape == (2, cfg.out_hw(last_hw), cfg.out_hw(last_hw), last_c)
    assert jnp.all(jnp.isfinite(img))
    assert float(jnp.max(jnp.abs(img))) <= 1.0  # tanh output


@pytest.mark.parametrize("method", ["conventional", "unified", "pallas"])
def test_methods_agree_in_generator(method):
    cfg = _tiny(gan.DCGAN, scale=64)
    params = gan.generator_init(jax.random.key(0), cfg)
    z = jax.random.normal(jax.random.key(1), (1, cfg.z_dim))
    want = gan.generator_apply(params, cfg, z, method="conventional")
    got = gan.generator_apply(params, cfg, z, method=method)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flop_reduction_is_4x():
    """Paper Table 4 models all use 4x4 kernels: exactly 4x MAC reduction."""
    for cfg in gan.GAN_ZOO.values():
        conv = gan.generator_flops(cfg, method="conventional")
        segd = gan.generator_flops(cfg, method="segregated")
        assert conv == 4 * segd


def test_ebgan_memory_savings_matches_paper():
    """Paper: EB-GAN transpose conv layers save ~35 MB."""
    savings = gan.generator_memory_savings(gan.EBGAN)
    assert savings == pytest.approx(35_534_592, rel=0.2)


def test_gan_training_step_improves():
    """Tiny DCGAN: one generator/discriminator step each runs and produces
    finite grads through the segregated op."""
    cfg = _tiny(gan.DCGAN, scale=64)
    gp = gan.generator_init(jax.random.key(0), cfg)
    last_hw, _, last_c = cfg.layers[-1]
    dp = gan.discriminator_init(
        jax.random.key(1), cfg.out_hw(last_hw), last_c
    )
    z = jax.random.normal(jax.random.key(2), (2, cfg.z_dim))

    def g_loss(gp):
        fake = gan.generator_apply(gp, cfg, z, method="unified")
        return -jnp.mean(gan.discriminator_apply(dp, fake))

    grads = jax.grad(g_loss)(gp)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)
