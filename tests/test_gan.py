"""GAN generator zoo (paper Table 4) + trainability of the segregated op."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gan


def _tiny(cfg, scale=16):
    layers = tuple(
        (hw, max(cin // scale, 2), max(cout // scale, 2))
        for hw, cin, cout in cfg.layers
    )
    return dataclasses.replace(cfg, layers=layers)


@pytest.mark.parametrize("name", list(gan.GAN_ZOO))
def test_generator_shapes(name):
    cfg = _tiny(gan.GAN_ZOO[name])
    params = gan.generator_init(jax.random.key(0), cfg)
    z = jax.random.normal(jax.random.key(1), (2, cfg.z_dim))
    img = gan.generator_apply(params, cfg, z, method="unified")
    last_hw, _, last_c = cfg.layers[-1]
    assert img.shape == (2, cfg.out_hw(last_hw), cfg.out_hw(last_hw), last_c)
    assert jnp.all(jnp.isfinite(img))
    assert float(jnp.max(jnp.abs(img))) <= 1.0  # tanh output


@pytest.mark.parametrize("method", ["conventional", "unified", "pallas"])
def test_methods_agree_in_generator(method):
    cfg = _tiny(gan.DCGAN, scale=64)
    params = gan.generator_init(jax.random.key(0), cfg)
    z = jax.random.normal(jax.random.key(1), (1, cfg.z_dim))
    want = gan.generator_apply(params, cfg, z, method="conventional")
    got = gan.generator_apply(params, cfg, z, method=method)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flop_reduction_is_4x():
    """Paper Table 4 models all use 4x4 kernels: exactly 4x MAC reduction
    (on the bare transpose-conv MACs — the epilogue's elementwise ops are
    method-independent and excluded from the paper's algebra)."""
    for cfg in gan.GAN_ZOO.values():
        conv = gan.generator_flops(cfg, method="conventional",
                                   include_epilogue=False)
        segd = gan.generator_flops(cfg, method="segregated",
                                   include_epilogue=False)
        assert conv == 4 * segd


def test_generator_flops_counts_epilogue_element_ops():
    """The default FLOP count includes what the fused kernel actually
    executes: one bias-add + one activation op per output element, on TOP
    of the transpose-conv MACs — identical extra term for every method."""
    from repro.core.segregation import output_size

    for cfg in gan.GAN_ZOO.values():
        epi_ops = sum(
            2 * output_size(hw, cfg.kernel, cfg.padding) ** 2 * cout
            for hw, _, cout in cfg.layers
        )
        for method in ("conventional", "segregated"):
            bare = gan.generator_flops(cfg, method=method,
                                       include_epilogue=False)
            full = gan.generator_flops(cfg, method=method)
            assert full == bare + epi_ops


def test_ebgan_memory_savings_matches_paper():
    """Paper Table 4: the EB-GAN stack's avoided upsampled-buffer traffic is
    ~35 MB — the reproduced figure must land within 10% of the paper's."""
    savings = gan.generator_memory_savings(gan.EBGAN)
    assert savings == pytest.approx(35e6, rel=0.10)


# Golden per-GAN savings (bytes): sum over layers of the whole padded
# upsampled buffer (2N-1+2P)^2 * Cin * 4 (paper Table-4 convention,
# mode="buffer"). Pinned exactly so a regression in the memory model (or a
# silent GANConfig edit) can't drift unnoticed — EBGAN's value is the
# paper's ~35 MB figure.
GOLDEN_SAVINGS = {
    "dcgan": 4_787_712,
    "artgan": 3_543_040,
    "gpgan": 2_393_856,
    "ebgan": 35_534_592,
}


@pytest.mark.parametrize("name", list(gan.GAN_ZOO))
def test_memory_savings_golden_values(name):
    assert gan.generator_memory_savings(gan.GAN_ZOO[name]) == (
        GOLDEN_SAVINGS[name]
    )


def test_memory_savings_goldens_cover_the_zoo():
    assert set(GOLDEN_SAVINGS) == set(gan.GAN_ZOO)


def test_memory_savings_epilogue_counts_eliminated_intermediates():
    """include_epilogue=True adds exactly the post-op round trips the fused
    epilogue eliminates: 2 extra reads + 2 extra writes of each layer's
    (M, M, Cout) fp32 output map. The default stays the paper's figure."""
    from repro.core.segregation import output_size

    for name, cfg in gan.GAN_ZOO.items():
        epi_bytes = sum(
            4 * output_size(hw, cfg.kernel, cfg.padding) ** 2 * cout * 4
            for hw, _, cout in cfg.layers
        )
        assert gan.generator_memory_savings(cfg) == GOLDEN_SAVINGS[name]
        assert gan.generator_memory_savings(
            cfg, include_epilogue=True
        ) == GOLDEN_SAVINGS[name] + epi_bytes


def test_gan_training_step_improves():
    """Tiny DCGAN: one generator/discriminator step each runs and produces
    finite grads through the segregated op."""
    cfg = _tiny(gan.DCGAN, scale=64)
    gp = gan.generator_init(jax.random.key(0), cfg)
    last_hw, _, last_c = cfg.layers[-1]
    dp = gan.discriminator_init(
        jax.random.key(1), cfg.out_hw(last_hw), last_c
    )
    z = jax.random.normal(jax.random.key(2), (2, cfg.z_dim))

    def g_loss(gp):
        fake = gan.generator_apply(gp, cfg, z, method="unified")
        return -jnp.mean(gan.discriminator_apply(dp, fake))

    grads = jax.grad(g_loss)(gp)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)
