"""Per-architecture smoke tests: REDUCED same-family config, one forward /
train loss / prefill / decode step on CPU; asserts output shapes + no NaNs.

(The FULL configs are exercised only via the dry-run — ShapeDtypeStruct, no
allocation.)
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models.lm import build_model

B, S = 2, 64


def _batch(cfg):
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "targets": jnp.ones((B, S), jnp.int32),
    }
    if cfg.n_patches:
        batch["patch_embeds"] = jnp.zeros(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16
        )
        batch["targets"] = jnp.ones((B, S + cfg.n_patches), jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.zeros((B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_smoke(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)

    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"

    prefill_batch = {k: v for k, v in batch.items() if k != "targets"}
    logits, cache = model.prefill(params, prefill_batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))

    dec_logits, new_cache = model.decode_step(
        params, cache,
        {"tokens": jnp.ones((B, 1), jnp.int32),
         "pos": jnp.full((B,), S - 1, jnp.int32)},
    )
    assert dec_logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(dec_logits.astype(jnp.float32)))
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_counts(arch):
    """Sanity-check the FULL configs' parameter counts against their names
    (abstract shapes only — no allocation)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "llava-next-mistral-7b": (6.5e9, 8.5e9),
        "llama3-8b": (7e9, 9e9),
        "yi-9b": (8e9, 10e9),
        "codeqwen1.5-7b": (6.5e9, 8.5e9),
        "qwen2-0.5b": (4e8, 7e8),
        "whisper-large-v3": (1.4e9, 2.2e9),   # backbone enc+dec
        "jamba-1.5-large-398b": (3.3e11, 4.6e11),
        "dbrx-132b": (1.15e11, 1.5e11),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        # the assigned dims (12L, d=768, d_ff=0, tied 50k vocab) give 74M
        # with unexpanded mLSTM/sLSTM blocks; the released 125M uses
        # projection-factor-2 blocks the assignment's dims don't specify
        "xlstm-125m": (0.6e8, 1.8e8),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n:.3e} params"
    if cfg.moe.n_experts:
        assert cfg.active_param_count() < 0.35 * n


def test_train_step_decreases_loss():
    """End-to-end: a reduced dense model actually learns on synthetic data."""
    from repro.data import SyntheticTokens
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import (
        TrainConfig, init_train_state, make_train_step,
    )

    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3), warmup_steps=2, total_steps=30
    )
    params, opt = init_train_state(model, jax.random.key(0), tc)
    step = jax.jit(make_train_step(model, tc))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, data.batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]
