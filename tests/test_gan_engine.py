"""Plan-served GAN inference engine: bucket policy, metrics, FIFO fairness,
deadline flush, backpressure, pad-and-mask equivalence with unbatched
generation, and zero retraces after warmup."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gan
from repro.serve import BucketPolicy, GanEngine, GenRequest, QueueFull
from repro.serve.batching import pow2_buckets
from repro.serve.metrics import ServeMetrics

_tiny = gan.reduced_config


class FakeClock:
    """Injectable clock for deterministic deadline / fairness tests."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _z(rng, n, z_dim):
    return rng.standard_normal((n, z_dim)).astype(np.float32)


@pytest.fixture(scope="module")
def tiny_dcgan():
    cfg = _tiny(gan.DCGAN)
    params = gan.generator_init(jax.random.key(0), cfg)
    return cfg, params


# ------------------------------------------------------------ bucket policy

def test_pow2_buckets():
    assert pow2_buckets(16) == (1, 2, 4, 8, 16)
    assert pow2_buckets(1) == (1,)
    with pytest.raises(ValueError):
        pow2_buckets(12)
    with pytest.raises(ValueError):
        pow2_buckets(0)


def test_bucket_for_picks_smallest_holding_bucket():
    p = BucketPolicy(buckets=(1, 2, 4, 8))
    assert [p.bucket_for(n) for n in (1, 2, 3, 4, 5, 8)] == [1, 2, 4, 4, 8, 8]
    with pytest.raises(ValueError):
        p.bucket_for(9)
    with pytest.raises(ValueError):
        p.bucket_for(0)


def test_policy_validation():
    with pytest.raises(ValueError):
        BucketPolicy(buckets=())
    with pytest.raises(ValueError):
        BucketPolicy(buckets=(4, 2, 8))          # not increasing
    with pytest.raises(ValueError):
        BucketPolicy(buckets=(2, 2, 4))          # duplicate
    with pytest.raises(ValueError):
        BucketPolicy(buckets=(1, 2), max_queue=1)  # < max bucket
    with pytest.raises(ValueError):
        BucketPolicy(max_wait_s=-1.0)


def test_pack_is_greedy_fifo_whole_requests():
    p = BucketPolicy(buckets=(1, 2, 4, 8))
    assert p.pack([]) == (0, 0)
    assert p.pack([1]) == (1, 1)
    assert p.pack([1, 3, 2, 1, 4]) == (4, 8)     # 1+3+2+1=7 -> bucket 8
    assert p.pack([8, 1]) == (1, 8)              # never split, never reorder
    assert p.pack([5, 4]) == (1, 8)              # 5+4 > 8: head only


def test_should_flush_full_and_deadline():
    p = BucketPolicy(buckets=(1, 2, 4, 8), max_wait_s=0.5)
    assert not p.should_flush([], 99.0)
    assert not p.should_flush([1, 2], 0.1)       # partial, young: wait
    assert p.should_flush([1, 2], 0.5)           # deadline hit
    assert p.should_flush([4, 4], 0.0)           # exactly full
    assert p.should_flush([4, 3, 2], 0.0)        # next req would overflow


# ---------------------------------------------------------------- metrics

def test_metrics_summary_math():
    m = ServeMetrics()
    m.record_admit(10.0)
    m.record_batch(3, 4, 0.5, now=11.0)
    m.record_batch(1, 4, 0.5, now=12.0)
    for lat in (0.1, 0.2, 0.3, 0.4):
        m.record_completion(lat)
    m.record_reject()
    s = m.summary()
    assert s["samples"] == 4 and s["batches"] == 2 and s["requests"] == 4
    assert s["pad_waste"] == pytest.approx(0.5)  # 4 of 8 rows were padding
    assert s["elapsed_s"] == pytest.approx(2.0)
    assert s["samples_per_s"] == pytest.approx(2.0)
    assert s["rejected"] == 1
    assert s["latency_s"]["p50"] == pytest.approx(0.25)
    assert s["latency_s"]["max"] == pytest.approx(0.4)
    assert "p99" in s["latency_s"]


def test_metrics_empty_summary():
    s = ServeMetrics().summary()
    assert s["pad_waste"] == 0.0 and s["samples_per_s"] == 0.0
    assert s["latency_s"]["p50"] == 0.0


# ----------------------------------------------------- engine: correctness

def test_pad_and_mask_matches_unbatched(tiny_dcgan):
    """Every admitted request's output is bitwise-equal to unbatched
    generator_apply on its own latents — padding rows and co-batched
    requests must not perturb a single bit."""
    cfg, params = tiny_dcgan
    eng = GanEngine(BucketPolicy(buckets=(1, 2, 4, 8), max_queue=64))
    eng.register(cfg, params)
    eng.warmup()

    rng = np.random.default_rng(0)
    reqs = [GenRequest("dcgan", _z(rng, n, cfg.z_dim))
            for n in (1, 3, 2, 1, 4, 2, 1, 5)]
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        ref = np.asarray(gan.generator_apply(params, cfg, jnp.asarray(r.z)))
        assert r.output.shape == ref.shape
        assert np.array_equal(np.asarray(r.output), ref), (
            f"request {r.rid} (n={r.n}) diverged from unbatched generation"
        )


def test_multi_model_registry_shares_one_engine(tiny_dcgan):
    """Two zoo generators served by the same engine, interleaved requests;
    each output still bitwise-matches its own model's unbatched call."""
    cfg_d, params_d = tiny_dcgan
    cfg_g = _tiny(gan.GPGAN)
    params_g = gan.generator_init(jax.random.key(1), cfg_g)

    eng = GanEngine(BucketPolicy(buckets=(1, 2, 4), max_queue=64))
    eng.register(cfg_d, params_d)
    eng.register(cfg_g, params_g)
    eng.warmup()

    rng = np.random.default_rng(1)
    reqs = []
    for i in range(8):
        name, cfg = (("dcgan", cfg_d), ("gpgan", cfg_g))[i % 2]
        reqs.append(GenRequest(name, _z(rng, 1 + i % 3, cfg.z_dim)))
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        cfg, params = ((cfg_d, params_d) if r.model == "dcgan"
                       else (cfg_g, params_g))
        ref = np.asarray(gan.generator_apply(params, cfg, jnp.asarray(r.z)))
        assert np.array_equal(np.asarray(r.output), ref)


def test_submit_validation(tiny_dcgan):
    cfg, params = tiny_dcgan
    eng = GanEngine(BucketPolicy(buckets=(1, 2), max_queue=16))
    eng.register(cfg, params)
    with pytest.raises(ValueError):                 # unknown model
        eng.submit(GenRequest("nope", np.zeros((1, cfg.z_dim), np.float32)))
    with pytest.raises(ValueError):                 # wrong z shape
        eng.submit(GenRequest("dcgan", np.zeros((3,), np.float32)))
    with pytest.raises(ValueError):                 # oversize request
        eng.submit(GenRequest("dcgan", np.zeros((3, cfg.z_dim), np.float32)))
    with pytest.raises(ValueError):                 # duplicate register
        eng.register(cfg, params)


def test_zero_row_request_rejected_at_admission(tiny_dcgan):
    """A (0, z_dim) request must be refused at submit — admitted, it would
    poison the queue head (no bucket holds 0 rows) and wedge the loop."""
    cfg, params = tiny_dcgan
    eng = GanEngine(BucketPolicy(buckets=(1, 2), max_queue=16))
    eng.register(cfg, params)
    with pytest.raises(ValueError):
        eng.submit(GenRequest("dcgan", np.zeros((0, cfg.z_dim), np.float32)))
    assert eng.queued_requests == 0
    rng = np.random.default_rng(12)                 # engine still serves
    ok = GenRequest("dcgan", _z(rng, 1, cfg.z_dim))
    eng.serve([ok])
    assert ok.done


# ------------------------------------------------------- engine: fairness

def test_fifo_order_within_model(tiny_dcgan):
    """Single model: requests complete in submission order even when batch
    formation groups them differently."""
    cfg, params = tiny_dcgan
    eng = GanEngine(BucketPolicy(buckets=(1, 2, 4), max_queue=64))
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(2)
    reqs = [GenRequest("dcgan", _z(rng, n, cfg.z_dim))
            for n in (1, 2, 1, 3, 1, 1, 2)]
    eng.serve(reqs)
    assert [r.rid for r in eng.completed] == sorted(r.rid for r in reqs)


def test_fifo_fairness_across_models_serves_oldest_head_first(tiny_dcgan):
    """Cross-model fairness: each dispatch serves the model whose head
    request has waited longest — a busy model cannot starve a quiet one."""
    cfg_d, params_d = tiny_dcgan
    cfg_g = _tiny(gan.GPGAN)
    params_g = gan.generator_init(jax.random.key(1), cfg_g)

    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2), max_wait_s=0.0, max_queue=64),
        clock=clock,
    )
    eng.register(cfg_d, params_d)
    eng.register(cfg_g, params_g)
    eng.warmup()

    rng = np.random.default_rng(3)
    # dcgan floods at t=0,1,2; gpgan arrives at t=0.5 — it must be served
    # right after the first dcgan batch, not after the whole flood
    a0 = GenRequest("dcgan", _z(rng, 1, cfg_d.z_dim))
    a1 = GenRequest("dcgan", _z(rng, 1, cfg_d.z_dim))
    a2 = GenRequest("dcgan", _z(rng, 1, cfg_d.z_dim))
    b0 = GenRequest("gpgan", _z(rng, 1, cfg_g.z_dim))
    for t, r in [(0.0, a0), (0.0, a1), (0.5, b0), (2.0, a2)]:
        clock.t = t
        eng.submit(r)
    while eng.step(drain=True):
        pass
    assert [r.rid for r in eng.completed] == [a0.rid, a1.rid, b0.rid, a2.rid]


# ------------------------------------------------ engine: deadline flush

def test_deadline_flushes_partial_batch(tiny_dcgan):
    """A lone small request does not wait for a full bucket: the step loop
    refuses to dispatch before max_wait_s and flushes right after it."""
    cfg, params = tiny_dcgan
    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2, 4, 8), max_wait_s=0.25, max_queue=64),
        clock=clock,
    )
    eng.register(cfg, params)
    eng.warmup()

    rng = np.random.default_rng(4)
    r = GenRequest("dcgan", _z(rng, 2, cfg.z_dim))
    eng.submit(r)
    assert not eng.step()          # young partial batch: hold
    clock.advance(0.1)
    assert not eng.step()          # still under the deadline
    clock.advance(0.2)             # 0.3s waited > 0.25s max_wait
    assert eng.step()
    assert r.done and eng.metrics.batches == 1
    # padded into the smallest holding bucket, not the largest
    assert eng.metrics.padded == 2 and eng.metrics.samples == 2


def test_full_bucket_flushes_immediately(tiny_dcgan):
    cfg, params = tiny_dcgan
    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2, 4), max_wait_s=999.0, max_queue=64),
        clock=clock,
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(5)
    for n in (2, 2):               # fills the max bucket exactly
        eng.submit(GenRequest("dcgan", _z(rng, n, cfg.z_dim)))
    assert eng.step()              # no deadline needed
    assert eng.metrics.samples == 4 and eng.metrics.pad_waste == 0.0


# ------------------------------------------------- engine: backpressure

def test_backpressure_rejects_above_queue_bound(tiny_dcgan):
    cfg, params = tiny_dcgan
    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2, 4), max_wait_s=999.0, max_queue=6),
        clock=clock,
    )
    eng.register(cfg, params)
    rng = np.random.default_rng(6)
    eng.submit(GenRequest("dcgan", _z(rng, 4, cfg.z_dim)))
    eng.submit(GenRequest("dcgan", _z(rng, 2, cfg.z_dim)))
    overflow = GenRequest("dcgan", _z(rng, 1, cfg.z_dim))
    with pytest.raises(QueueFull):
        eng.submit(overflow)
    assert overflow.rid == -1 and eng.queued_requests == 2
    assert eng.metrics.rejected == 1
    # draining frees the queue: the same request is admissible again
    while eng.step(drain=True):
        pass
    eng.submit(overflow)
    assert eng.queued_requests == 1


# --------------------------------------------- engine: zero retraces

def test_zero_retraces_after_warmup(tiny_dcgan, tconv_trace_counter):
    """The tentpole invariant: after warmup, a mixed-size request stream
    causes ZERO new layer traces (every bucket's plan traced exactly once)
    and the engine's trace-time recompile counter stays frozen."""
    cfg, params = tiny_dcgan
    eng = GanEngine(BucketPolicy(buckets=(1, 2, 4, 8), max_queue=256))
    eng.register(cfg, params)
    eng.warmup()

    # warmup traced each (bucket, layer) plan exactly once
    assert eng.warmup_recompiles == 4              # one executable per bucket
    assert len(tconv_trace_counter) == 4 * len(cfg.layers)
    assert all(c == 1 for c in tconv_trace_counter.values())
    warm = dict(tconv_trace_counter)

    rng = np.random.default_rng(7)
    for _ in range(3):             # several waves of mixed-size traffic
        reqs = [GenRequest("dcgan", _z(rng, 1 + int(n), cfg.z_dim))
                for n in rng.integers(0, 8, size=9)]
        eng.serve(reqs)
        assert all(r.done for r in reqs)

    assert tconv_trace_counter == warm, "steady-state serving retraced"
    assert eng.metrics.recompiles == eng.warmup_recompiles


def test_unwarmed_engine_compiles_inline_and_counts_it(tiny_dcgan):
    """Skipping warmup still serves correctly — the recompile counter is
    how the metrics surface the inline compile cost."""
    cfg, params = tiny_dcgan
    eng = GanEngine(BucketPolicy(buckets=(1, 2), max_queue=16))
    eng.register(cfg, params)
    assert eng.metrics.recompiles == 0
    rng = np.random.default_rng(8)
    reqs = [GenRequest("dcgan", _z(rng, 2, cfg.z_dim))]
    eng.serve(reqs)
    assert reqs[0].done
    assert eng.metrics.recompiles == 1             # paid inline, visible
    eng.serve([GenRequest("dcgan", _z(rng, 2, cfg.z_dim))])
    assert eng.metrics.recompiles == 1             # second hit: cached


# ---------------------------------------------------------- replay mode

def test_replay_serves_trace_to_completion(tiny_dcgan):
    cfg, params = tiny_dcgan
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2, 4), max_wait_s=0.002, max_queue=64)
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(9)
    reqs = [GenRequest("dcgan", _z(rng, 1 + i % 2, cfg.z_dim))
            for i in range(6)]
    arrivals = [i * 1e-3 for i in range(6)]
    eng.replay(reqs, arrivals)
    assert all(r.done for r in reqs)
    assert eng.metrics.requests == 6


def test_replay_sheds_load_under_backpressure(tiny_dcgan):
    """QueueFull during replay drops the one rejected request (counted in
    metrics) and keeps serving the rest of the trace — a hot burst must not
    abort the whole replay."""
    cfg, params = tiny_dcgan
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2), max_wait_s=999.0, max_queue=2)
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(11)
    reqs = [GenRequest("dcgan", _z(rng, 1, cfg.z_dim)) for _ in range(6)]
    eng.replay(reqs, [0.0] * 6)        # burst into a 2-sample queue bound
    served = [r for r in reqs if r.done]
    assert eng.metrics.rejected == 6 - len(served) > 0
    assert eng.metrics.requests == len(served)


def test_replay_rejects_unsorted_arrivals(tiny_dcgan):
    cfg, params = tiny_dcgan
    eng = GanEngine(BucketPolicy(buckets=(1, 2), max_queue=16))
    eng.register(cfg, params)
    rng = np.random.default_rng(10)
    reqs = [GenRequest("dcgan", _z(rng, 1, cfg.z_dim)) for _ in range(2)]
    with pytest.raises(ValueError):
        eng.replay(reqs, [0.2, 0.1])


# ------------------------------------------------- engine: request deadlines

def test_expired_request_rejected_not_served_stale(tiny_dcgan):
    """A queued request whose deadline passes is dropped and counted —
    never dispatched late as if nothing happened."""
    cfg, params = tiny_dcgan
    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2, 4), max_wait_s=999.0, max_queue=64),
        clock=clock,
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(11)
    impatient = GenRequest("dcgan", _z(rng, 1, cfg.z_dim), deadline_s=0.05)
    patient = GenRequest("dcgan", _z(rng, 1, cfg.z_dim))
    eng.submit(impatient)
    eng.submit(patient)
    clock.advance(0.2)             # past the impatient deadline
    assert eng.step(drain=True)    # dispatches what's still valid
    assert impatient.expired and not impatient.done
    assert impatient.output is None
    assert patient.done and not patient.expired
    assert eng.metrics.expired == 1
    assert eng.metrics.requests == 1   # only the served one completed
    # the dispatched batch never contained the expired rows
    assert eng.metrics.samples == 1


def test_expired_mid_queue_behind_patient_head(tiny_dcgan):
    """Deadlines are per-request: a short-deadline request can expire
    BEHIND a patient head without disturbing FIFO order for the rest."""
    cfg, params = tiny_dcgan
    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2, 4), max_wait_s=999.0, max_queue=64),
        clock=clock,
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(12)
    head = GenRequest("dcgan", _z(rng, 1, cfg.z_dim))
    mid = GenRequest("dcgan", _z(rng, 1, cfg.z_dim), deadline_s=0.01)
    tail = GenRequest("dcgan", _z(rng, 1, cfg.z_dim))
    for r in (head, mid, tail):
        eng.submit(r)
    clock.advance(0.1)
    assert eng.step(drain=True)
    assert mid.expired and not mid.done
    assert head.done and tail.done
    assert [r.rid for r in eng.completed] == [head.rid, tail.rid]
    assert eng.metrics.expired == 1


def test_serve_all_expired_drains_cleanly(tiny_dcgan):
    """step() must terminate (not spin) when everything queued expires."""
    cfg, params = tiny_dcgan
    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2), max_wait_s=999.0, max_queue=64),
        clock=clock,
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(13)
    reqs = [GenRequest("dcgan", _z(rng, 1, cfg.z_dim), deadline_s=0.01)
            for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    clock.advance(1.0)
    assert not eng.step(drain=True)   # purge drains the queue, nothing runs
    assert eng.queued_requests == 0
    assert all(r.expired and not r.done for r in reqs)
    assert eng.metrics.expired == 3 and eng.metrics.batches == 0


def test_deadline_validation(tiny_dcgan):
    cfg, params = tiny_dcgan
    eng = GanEngine(BucketPolicy(buckets=(1, 2), max_queue=64))
    eng.register(cfg, params)
    rng = np.random.default_rng(14)
    with pytest.raises(ValueError):
        eng.submit(GenRequest("dcgan", _z(rng, 1, cfg.z_dim), deadline_s=0.0))
    with pytest.raises(ValueError):
        eng.submit(GenRequest("dcgan", _z(rng, 1, cfg.z_dim),
                              deadline_s=-1.0))


# ------------------------------------- engine: terminal-state accounting

def test_expired_request_stamps_t_done_and_residence(tiny_dcgan):
    """Expiry is a terminal resolution like any other: ``t_done`` is
    stamped at purge so ``latency_s`` (queue residence) is measurable, and
    the residence lands in ``metrics.expired_residence_s``."""
    cfg, params = tiny_dcgan
    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2), max_wait_s=999.0, max_queue=64),
        clock=clock,
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(15)
    req = GenRequest("dcgan", _z(rng, 1, cfg.z_dim), deadline_s=0.05)
    eng.submit(req)
    t_submit = req.t_submit
    clock.advance(0.3)
    eng.step(drain=True)
    assert req.expired and req.terminal_state == "expired"
    assert req.t_done == clock.t                  # stamped at purge
    assert req.latency_s == pytest.approx(clock.t - t_submit)
    assert np.isfinite(req.latency_s)
    assert eng.metrics.expired_residence_s == [pytest.approx(0.3)]
    assert eng.metrics.summary()["expired_residence_s"]["p50"] == (
        pytest.approx(0.3)
    )


def test_replay_malformed_request_failed_not_abort(tiny_dcgan):
    """A live trace must keep serving through a bad request: a malformed
    submit (unknown model / wrong latent shape) is terminally failed and
    counted, and the rest of the trace is served — the replay never
    aborts with the queue half-full."""
    cfg, params = tiny_dcgan
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2), max_wait_s=0.001, max_queue=64)
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(16)
    good_a = GenRequest("dcgan", _z(rng, 1, cfg.z_dim))
    unknown = GenRequest("nope", _z(rng, 1, cfg.z_dim))
    bad_shape = GenRequest("dcgan", _z(rng, 1, cfg.z_dim + 3))
    good_b = GenRequest("dcgan", _z(rng, 2, cfg.z_dim))
    reqs = [good_a, unknown, bad_shape, good_b]
    eng.replay(reqs, [0.0, 0.001, 0.002, 0.003])
    assert good_a.done and good_b.done
    assert unknown.failed and not unknown.done
    assert bad_shape.failed and not bad_shape.done
    assert unknown.terminal_state == "failed"
    assert np.isfinite(unknown.latency_s)         # t_done stamped
    assert eng.metrics.malformed == 2
    assert eng.metrics.requests == 2
    ledger = eng.conservation()
    assert ledger["ok"] and ledger["admitted"] == 2


def test_conservation_ledger_plain_engine(tiny_dcgan):
    """The conservation ledger on the base engine: done + expired +
    rejected splits exactly, mid-run the still-queued term balances."""
    cfg, params = tiny_dcgan
    clock = FakeClock()
    eng = GanEngine(
        BucketPolicy(buckets=(1, 2), max_wait_s=999.0, max_queue=2),
        clock=clock,
    )
    eng.register(cfg, params)
    eng.warmup()
    rng = np.random.default_rng(17)
    served = GenRequest("dcgan", _z(rng, 1, cfg.z_dim))
    doomed = GenRequest("dcgan", _z(rng, 1, cfg.z_dim), deadline_s=0.01)
    eng.submit(served)
    eng.submit(doomed)
    with pytest.raises(QueueFull):
        eng.submit(GenRequest("dcgan", _z(rng, 1, cfg.z_dim)))
    mid = eng.conservation()
    assert mid["ok"] and mid["queued"] == 2 and mid["resolved"] == 0
    clock.advance(0.1)
    while eng.step(drain=True):
        pass
    end = eng.conservation()
    assert end["ok"] and end["queued"] == 0
    assert end["done"] == 1 and end["expired"] == 1 and end["rejected"] == 1
    assert end["admitted"] == end["resolved"] == 2
