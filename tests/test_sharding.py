"""Sharding rules: param specs, divisibility filtering, constrain no-op."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as sh
from repro.models.lm import build_model


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = sh.constrain(x, "data", None)
    assert (y == x).all()


def _with_mesh(fn):
    """Run fn with a fake 16x16 production mesh visible to the rule engine
    (set_mesh requires real devices; the rules only read names/sizes)."""
    mesh = sh.abstract_mesh((16, 16), ("data", "model"))
    orig = getattr(jax.sharding, "get_abstract_mesh", None)
    jax.sharding.get_abstract_mesh = lambda: mesh
    try:
        return fn()
    finally:
        if orig is None:
            del jax.sharding.get_abstract_mesh
        else:
            jax.sharding.get_abstract_mesh = orig


def test_param_specs_llama3():
    cfg = get_config("llama3-8b")
    params = build_model(cfg).abstract_params()

    def check():
        specs = sh.param_specs(params, cfg.fsdp)
        # embedding vocab-parallel with stacked-layer-free rank
        assert specs["embed"]["w"] == P("model", None)
        l0 = specs["layers"][0]
        # stacked (n_periods, d, H*hd): leading None + column-parallel
        assert l0["mixer"]["attn"]["wq"]["w"] == P(None, None, "model")
        assert l0["mixer"]["attn"]["wo"]["w"] == P(None, "model", None)
        assert l0["ffn"]["w_gate"]["w"] == P(None, None, "model")
        assert l0["ffn"]["w_down"]["w"] == P(None, "model", None)
        # norms replicated
        assert l0["mixer_norm"]["scale"] in (P(), P(None))
    _with_mesh(check)


def test_param_specs_drop_nondivisible():
    cfg = get_config("xlstm-125m")
    params = build_model(cfg).abstract_params()

    def check():
        specs = sh.param_specs(params, False)
        # w_if: (periods, d, 2*nh)=(...,8): 8 % 16 != 0 -> axis dropped
        assert specs["layers"][0]["mixer"]["mlstm"]["w_if"] in (
            P(), P(None, None, None)
        )
    _with_mesh(check)


def test_fsdp_adds_data_axis():
    cfg = get_config("dbrx-132b")
    params = build_model(cfg).abstract_params()

    def check():
        specs = sh.param_specs(params, True)
        l0 = specs["layers"][0]
        assert l0["ffn"]["experts"]["w_gate"] == P(None, "model", "data", None)
    _with_mesh(check)


def test_filter_divisibility():
    def check():
        assert sh._filter(P("model"), (32,)) == P("model")
        assert sh._filter(P("model"), (8,)) is None
        assert sh._filter(P(("data", "model")), (256,)) == P(("data", "model"))
        assert sh._filter(P("nope", "model"), (4, 32)) == P(None, "model")
    _with_mesh(check)
