"""End-to-end behaviour tests for the system (paper claims + framework)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gan


def test_paper_claim_exactness_end_to_end():
    """The headline claim: unified segregation is an EXACT optimization —
    same output feature map as Algorithm 1 on a GAN-shaped stack."""
    cfg = gan.GAN_ZOO["dcgan"]
    small = gan.GANConfig("t", 16, tuple(
        (hw, cin // 32, max(cout // 32, 1)) for hw, cin, cout in cfg.layers
    ))
    params = gan.generator_init(jax.random.key(0), small)
    z = jax.random.normal(jax.random.key(1), (2, small.z_dim))
    a = gan.generator_apply(params, small, z, method="conventional")
    b = gan.generator_apply(params, small, z, method="unified")
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_flop_advantage_monotone_in_kernel():
    from repro.core import flop_count

    for n in (2, 3, 4, 5, 6, 7):
        c = flop_count(32, n, 4, 4, 0, method="conventional")
        s = flop_count(32, n, 4, 4, 0, method="segregated")
        assert c / s > 3.0, (n, c / s)


def test_train_serve_round_trip():
    """Train a reduced LM a few steps, then serve greedy tokens from it."""
    from repro.configs import get_config, reduced
    from repro.data import SyntheticTokens
    from repro.models.lm import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import (
        TrainConfig, init_train_state, make_train_step,
    )

    cfg = reduced(get_config("llama3-8b"))
    model = build_model(cfg)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=1,
                     total_steps=10)
    params, opt = init_train_state(model, jax.random.key(0), tc)
    step = jax.jit(make_train_step(model, tc))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=2)
    for i in range(5):
        params, opt, metrics = step(params, opt, data.batch(i))
    assert np.isfinite(float(metrics["loss"]))

    # serve: prefill 8 tokens then decode 4 greedily
    toks = data.batch(99)["tokens"][:, :8]
    logits, cache = model.prefill(params, {"tokens": toks})
    cache = jax.tree_util.tree_map(
        lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, 8)] + [(0, 0)] * (a.ndim - 3))
        if a.ndim >= 4 else a,
        cache,
    )
    out = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for t in range(8, 12):
        logits, cache = model.decode_step(
            params, cache, {"tokens": tok, "pos": jnp.full((2,), t)}
        )
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(tok)
    gen = jnp.concatenate(out, 1)
    assert gen.shape == (2, 4)
    assert int(gen.min()) >= 0 and int(gen.max()) < cfg.vocab_size
